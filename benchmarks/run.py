"""Benchmark aggregator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. Slow real-process suites
(runtime_bench) run last; pass --fast to skip them.

Also writes ``BENCH_checkpoint.json`` at the repo root: machine-readable
old-vs-new checkpoint write/read/recovery timings, so future PRs have a
perf trajectory to regress against. ``--check-regression`` re-measures
the checkpoint/recovery numbers and exits nonzero when any new-path
number regressed >20% against the committed file.
"""
from __future__ import annotations

import json
import os
import sys
import traceback

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# make `python benchmarks/run.py` work from anywhere: the sibling bench
# modules import as the `benchmarks` namespace package off the repo root
if REPO_ROOT not in sys.path:
    sys.path.insert(0, REPO_ROOT)
BENCH_JSON = os.path.join(REPO_ROOT, "BENCH_checkpoint.json")
REGRESSION_TOLERANCE = 0.20


def write_bench_json(ckpt_io: dict | None, e2e: dict | None,
                     growback: dict | None = None,
                     failover: dict | None = None,
                     serving: dict | None = None,
                     rehost: dict | None = None,
                     path: str = BENCH_JSON) -> bool:
    """Returns True only when the file was actually (re)written."""
    if not ckpt_io:
        return False
    doc = {
        "state_mb": ckpt_io.get("state_mb"),
        "n_shards": ckpt_io.get("n_shards"),
        "old": {"write_s": ckpt_io.get("npz_write_s"),
                "read_s": ckpt_io.get("npz_read_s")},
        "new": {"write_s": ckpt_io.get("bin_write_s"),
                "read_s": ckpt_io.get("bin_read_s"),
                "async_submit_s": ckpt_io.get("bin_async_submit_s")},
        "delta": {"write_s": ckpt_io.get("bin_delta_write_s"),
                  "read_s": ckpt_io.get("bin_delta_read_s"),
                  "bytes_frac": ckpt_io.get("delta_bytes_frac"),
                  "dirty_frac": ckpt_io.get("delta_dirty_frac")},
        # device dirty-tile gather: transferred D2H bytes per delta save
        # as a fraction of a full-state drain (proportional-to-dirt)
        "dirty_gather": {
            "d2h_frac": ckpt_io.get("delta_d2h_frac"),
            "d2h_bytes": ckpt_io.get("delta_d2h_bytes"),
            "full_d2h_bytes": ckpt_io.get("delta_full_d2h_bytes")},
        # background re-base: chained vs compacted restore cost
        "rebase": {
            "chained_read_s": ckpt_io.get("chained_read_s"),
            "rebased_read_s": ckpt_io.get("rebased_read_s"),
            "read_speedup": ckpt_io.get("rebase_read_speedup"),
            "chain_links": ckpt_io.get("rebase_chain_links")},
        "speedup": {"write": ckpt_io.get("write_speedup"),
                    "read": ckpt_io.get("read_speedup")},
        "memory_copy_s": ckpt_io.get("memory_copy_s"),
    }
    if e2e:
        doc["old"]["recovery_e2e_s"] = e2e["recovery_e2e_old_s"]
        doc["new"]["recovery_e2e_s"] = e2e["recovery_e2e_new_s"]
        doc["speedup"]["recovery"] = e2e["recovery_speedup"]
        doc["recovery_ranks"] = e2e["ranks"]
    if growback:
        # elastic lifecycle on the live runtime: shrink -> grow-back
        doc["growback"] = {"shrink_s": growback.get("shrink_s"),
                           "grow_s": growback.get("grow_s"),
                           "e2e_s": growback.get("growback_e2e_s")}
    elif os.path.exists(path):
        # --fast runs skip the real-process growback: carry the
        # committed numbers forward instead of dropping the row
        with open(path) as f:
            prior = json.load(f).get("growback")
        if prior:
            doc["growback"] = prior
    if failover:
        # zero-rollback replica failover vs reinit, at the largest
        # measured rank count (live runtime)
        doc["failover"] = {
            "ranks": failover.get("largest_ranks"),
            "replica_e2e_s": failover.get("replica_e2e_s"),
            "reinit_e2e_s": failover.get("reinit_e2e_s"),
            "speedup": failover.get("speedup")}
    elif os.path.exists(path):
        with open(path) as f:
            prior = json.load(f).get("failover")
        if prior:
            doc["failover"] = prior
    if serving:
        # fault-tolerant serving under live load: the client-visible
        # recovery gap per strategy (counts are deterministic — any
        # drift is a semantics change, not noise)
        doc["serving"] = {
            "n_slots": serving.get("n_slots"),
            "tokens_total": serving.get("tokens_total"),
            "s_per_token": serving.get("s_per_token"),
            "reinit": {
                "tokens_to_first_recovered_token":
                    serving["reinit"]["tokens_to_first_recovered_token"],
                "replayed_tokens": serving["reinit"]["replayed_tokens"],
                "requests_dropped": serving["reinit"]["requests_dropped"]},
            "replica": {
                "tokens_to_first_recovered_token":
                    serving["replica"]["tokens_to_first_recovered_token"],
                "replayed_tokens": serving["replica"]["replayed_tokens"],
                "requests_dropped":
                    serving["replica"]["requests_dropped"]},
            "ttfrt_speedup": serving.get("ttfrt_speedup"),
        }
    elif os.path.exists(path):
        with open(path) as f:
            prior = json.load(f).get("serving")
        if prior:
            doc["serving"] = prior
    if rehost:
        # gray-failure mitigation on the live runtime: sustained
        # slowdown -> straggler drain -> repaired node grows back
        doc["rehost"] = {"detect_s": rehost.get("detect_s"),
                         "shrink_s": rehost.get("shrink_s"),
                         "grow_s": rehost.get("grow_s"),
                         "e2e_s": rehost.get("e2e_s"),
                         "break_even_factor":
                             rehost.get("break_even_factor")}
    elif os.path.exists(path):
        with open(path) as f:
            prior = json.load(f).get("rehost")
        if prior:
            doc["rehost"] = prior
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return True


def check_regression(path: str = BENCH_JSON,
                     tolerance: float = REGRESSION_TOLERANCE) -> int:
    """Re-measure the fast-path checkpoint/recovery numbers and compare
    against the committed baseline. >`tolerance` slower on any new-path
    write/read/recovery number is a failure (exit 1). Speedups or small
    noise pass."""
    if not os.path.exists(path):
        print(f"regression_check_skipped,0,no_baseline:{path}")
        return 0
    with open(path) as f:
        committed = json.load(f)
    from benchmarks import checkpoint_bench, recovery_time, runtime_bench

    # the growback/failover rows only gate when the committed baseline
    # has them (each real-process pass is ~15 s — skip otherwise)
    gate_growback = bool(committed.get("growback", {}).get("e2e_s"))
    gate_rehost = bool(committed.get("rehost", {}).get("e2e_s"))
    gate_failover = bool(committed.get("failover", {}).get("replica_e2e_s"))
    gate_rebase = bool(committed.get("rebase", {}).get("rebased_read_s"))
    gate_serving = bool((committed.get("serving") or {})
                        .get("reinit", {})
                        .get("tokens_to_first_recovered_token"))

    def measure() -> dict:
        ckpt_io = checkpoint_bench.bench_file_io()
        e2e = recovery_time.e2e_rows(ckpt_io)
        out = {
            ("new", "write_s"): ckpt_io.get("bin_write_s"),
            ("new", "read_s"): ckpt_io.get("bin_read_s"),
            ("new", "recovery_e2e_s"): e2e["recovery_e2e_new_s"],
            ("delta", "write_s"): ckpt_io.get("bin_delta_write_s"),
            ("delta", "read_s"): ckpt_io.get("bin_delta_read_s"),
            ("delta", "bytes_frac"): ckpt_io.get("delta_bytes_frac"),
            # the gather's D2H fraction gates like a timing: lower is
            # better, >20% growth means dirt is leaking past the gather
            ("dirty_gather", "d2h_frac"): ckpt_io.get("delta_d2h_frac"),
        }
        if gate_rebase:
            rb = checkpoint_bench.bench_rebase()
            out[("rebase", "rebased_read_s")] = rb.get("rebased_read_s")
        if gate_growback:
            gb = runtime_bench.bench_growback(report=lambda *_: None)
            out[("growback", "e2e_s")] = gb.get("growback_e2e_s")
        if gate_rehost:
            rh = runtime_bench.bench_rehost(report=lambda *_: None)
            out[("rehost", "e2e_s")] = rh.get("e2e_s")
        if gate_failover:
            fo = runtime_bench.bench_failover(report=lambda *_: None,
                                              sizes=((2, 2),))
            out[("failover", "replica_e2e_s")] = fo.get("replica_e2e_s")
        return out

    # best of three full passes: container CPU/disk contention makes a
    # single wall-time sample far too noisy to gate on (observed >2x
    # run-to-run spread on the ~100 ms IO numbers under load)
    passes = [measure() for _ in range(3)]
    fresh = {k: min((p[k] for p in passes if p[k] is not None),
                    default=None) for k in passes[0]}
    failures = 0
    if gate_serving:
        # serving gates on deterministic token COUNTS (seeded load,
        # greedy decode) — one pass suffices, there is no timing noise
        from benchmarks import serve_bench
        sv = serve_bench.bench_serving(report=lambda *_: None)
        for strat in ("reinit", "replica"):
            now = sv[strat]["tokens_to_first_recovered_token"]
            base = committed["serving"][strat][
                "tokens_to_first_recovered_token"]
            dropped = sv[strat]["requests_dropped"]
            ok = (dropped == 0 and now is not None
                  and now <= base * (1.0 + tolerance))
            if not ok:
                failures += 1
            print(f"regress_serving_{strat}_ttfrt,{-1 if now is None else now},"
                  f"base={base};dropped={dropped};"
                  f"{'OK' if ok else 'REGRESSED'}")
    for (group, key), now in fresh.items():
        base = (committed.get(group) or {}).get(key)
        if base is None or now is None or base <= 0:
            print(f"regress_{group}_{key},0,no_baseline")
            continue
        ratio = now / base
        status = "OK" if ratio <= 1.0 + tolerance else "REGRESSED"
        if status == "REGRESSED":
            failures += 1
        print(f"regress_{group}_{key},{now * 1e6:.0f},"
              f"base={base:.6f};ratio={ratio:.2f};{status}")
    print(f"regression_check,{failures},"
          f"tolerance={tolerance:.0%};{'FAIL' if failures else 'PASS'}")
    return 1 if failures else 0


def main() -> None:
    fast = "--fast" in sys.argv
    # nightly variant: re-run the delta/gather/rebase benches on a 4x
    # larger state (D2H proportionality must hold where it matters)
    large = "--large-state" in sys.argv
    if "--check-regression" in sys.argv:
        print("name,us_per_call,derived")
        sys.exit(check_regression())
    from benchmarks import (app_overhead, checkpoint_bench, recovery_time,
                            step_bench, total_time, trainer_bench)

    print("name,us_per_call,derived")
    failures = 0

    # checkpoint substrate first: its measured IO feeds the end-to-end
    # recovery figures and BENCH_checkpoint.json
    ckpt_io = e2e = None
    try:
        ckpt_io = checkpoint_bench.run(report=print)
    except Exception:                     # noqa: BLE001
        failures += 1
        print("table2_checkpointing_FAILED,0,error")
        traceback.print_exc()
    if large:
        try:
            big = checkpoint_bench.bench_delta_io(mb=256.0)
            print(f"large_delta_write,"
                  f"{big['bin_delta_write_s'] * 1e6:.0f},256MB_5%_dirty")
            print(f"large_delta_d2h_frac,0,"
                  f"frac={big['delta_d2h_frac']:.4f}")
            rb = checkpoint_bench.bench_rebase(mb=64.0, links=12)
            print(f"large_rebase_read_speedup,0,"
                  f"x={rb['rebase_read_speedup']:.2f}")
        except Exception:                 # noqa: BLE001
            failures += 1
            print("large_state_bench_FAILED,0,error")
            traceback.print_exc()
    try:
        e2e = recovery_time.run(report=print, ckpt_io=ckpt_io)
    except Exception:                     # noqa: BLE001
        failures += 1
        print("fig6/fig7_recovery_FAILED,0,error")
        traceback.print_exc()
    growback = failover = rehost = None
    if not fast:
        from benchmarks import runtime_bench
        try:
            growback = runtime_bench.bench_growback(report=print)
        except Exception:                 # noqa: BLE001
            failures += 1
            print("bench_growback_FAILED,0,error")
            traceback.print_exc()
        try:
            rehost = runtime_bench.bench_rehost(report=print)
        except Exception:                 # noqa: BLE001
            failures += 1
            print("bench_rehost_FAILED,0,error")
            traceback.print_exc()
        try:
            failover = runtime_bench.bench_failover(report=print)
        except Exception:                 # noqa: BLE001
            failures += 1
            print("bench_failover_FAILED,0,error")
            traceback.print_exc()
    # serving recovery: in-process (no real process tree), so it runs in
    # --fast too; the nightly --large-state adds the wide-slot variant
    serving = None
    from benchmarks import serve_bench
    try:
        serving = serve_bench.run(report=print)
    except Exception:                     # noqa: BLE001
        failures += 1
        print("bench_serving_FAILED,0,error")
        traceback.print_exc()
    if large:
        try:
            serve_bench.run_wide(report=print)
        except Exception:                 # noqa: BLE001
            failures += 1
            print("bench_serving_wide_FAILED,0,error")
            traceback.print_exc()
    try:
        if write_bench_json(ckpt_io, e2e, growback, failover, serving,
                            rehost):
            print(f"bench_json_written,0,{BENCH_JSON}")
        else:
            print("bench_json_skipped,0,checkpoint_bench_failed")
    except Exception:                     # noqa: BLE001
        failures += 1
        traceback.print_exc()

    suites = [
        ("fig4 total time", total_time.run),
        ("fig5 app overhead", app_overhead.run),
        ("step microbench", step_bench.run),
        ("trainer recovery", trainer_bench.run),
    ]
    if not fast:
        from benchmarks import runtime_bench
        # growback/failover already measured above (feed the bench json)
        suites.append(("real-process runtime",
                       lambda report: runtime_bench.run(report,
                                                        growback=False,
                                                        failover=False)))

    for label, fn in suites:
        try:
            fn(report=print)
        except Exception:                 # noqa: BLE001
            failures += 1
            print(f"{label.replace(' ', '_')}_FAILED,0,error")
            traceback.print_exc()

    # roofline summary (requires dry-run artifacts; skip silently if absent)
    try:
        from benchmarks.roofline import all_rooflines
        rows = all_rooflines()
        for r in rows:
            print(f"roofline_{r.arch}_{r.shape}_{r.mesh},"
                  f"{r.t_overlap * 1e6:.0f},"
                  f"dom={r.dominant};frac={r.roofline_fraction:.3f}")
    except Exception:                     # noqa: BLE001
        print("roofline_artifacts_missing,0,run launch/dryrun first")

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
