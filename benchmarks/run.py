"""Benchmark aggregator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. Slow real-process suites
(runtime_bench) run last; pass --fast to skip them.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    fast = "--fast" in sys.argv
    from benchmarks import (app_overhead, checkpoint_bench, recovery_time,
                            step_bench, total_time, trainer_bench)
    suites = [
        ("fig6/fig7 recovery", recovery_time.run),
        ("fig4 total time", total_time.run),
        ("fig5 app overhead", app_overhead.run),
        ("table2 checkpointing", checkpoint_bench.run),
        ("step microbench", step_bench.run),
        ("trainer recovery", trainer_bench.run),
    ]
    if not fast:
        from benchmarks import runtime_bench
        suites.append(("real-process runtime", runtime_bench.run))

    print("name,us_per_call,derived")
    failures = 0
    for label, fn in suites:
        try:
            fn(report=print)
        except Exception:                     # noqa: BLE001
            failures += 1
            print(f"{label.replace(' ', '_')}_FAILED,0,error")
            traceback.print_exc()

    # roofline summary (requires dry-run artifacts; skip silently if absent)
    try:
        from benchmarks.roofline import all_rooflines
        rows = all_rooflines()
        for r in rows:
            print(f"roofline_{r.arch}_{r.shape}_{r.mesh},"
                  f"{r.t_overlap * 1e6:.0f},"
                  f"dom={r.dominant};frac={r.roofline_fraction:.3f}")
    except Exception:                         # noqa: BLE001
        print("roofline_artifacts_missing,0,run launch/dryrun first")

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
