"""§Roofline: three-term roofline per (arch × shape × mesh) cell.

Reads the dry-run artifacts (launch/dryrun.py JSON) and derives:

  compute_term    analytic_FLOPs / (chips · peak)         [s]
  memory_term     analytic_HBM_bytes / (chips · hbm_bw)   [s]
  collective_term HLO collective bytes (per-device SPMD
                  program, while-trip corrected) / link_bw [s]

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
The SPMD HLO is a per-device program, so its collective byte sum divided
by the per-link bandwidth IS the collective_bytes/(chips·link_bw) of the
assignment formula (global bytes = per-device × chips).

Step time bounds: overlap (= max term) and serial (= sum). The reported
roofline fraction is MODEL_FLOPS/(chips·peak·t_overlap) — how close the
useful model math runs to the hardware's peak if everything overlaps.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_LINK_BW = 50e9           # bytes/s / link

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    useful_ratio: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def t_overlap(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def t_serial(self) -> float:
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def roofline_fraction(self) -> float:
        return self.model_flops / (self.chips * PEAK_FLOPS *
                                   max(self.t_overlap, 1e-12))


def load_artifact(path: str):
    with open(path) as f:
        return json.load(f)


def roofline_of(art: dict) -> Roofline | None:
    if "skipped" in art:
        return None
    chips = art.get("chips", 256)
    fl = art["analytic"]["flops_total"]
    hb = art["analytic"]["hbm_bytes_total"]
    coll = art["collective_bytes"].get("total", 0)
    mf = art["analytic"]["model_flops"]
    return Roofline(
        arch=art["arch"], shape=art["shape"], mesh=art["mesh"],
        chips=chips,
        compute_s=fl / (chips * PEAK_FLOPS),
        memory_s=hb / (chips * HBM_BW),
        collective_s=coll / ICI_LINK_BW,
        model_flops=mf, hlo_flops=fl,
        useful_ratio=mf / max(fl, 1.0),
    )


def all_rooflines(art_dir: str = ART_DIR, mesh: str | None = None):
    out = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        art = load_artifact(path)
        if mesh and art.get("mesh") != mesh:
            continue
        r = roofline_of(art)
        if r:
            out.append(r)
    return out


def table(rows, fmt: str = "md") -> str:
    hdr = ["arch", "shape", "mesh", "compute_s", "memory_s",
           "collective_s", "dominant", "t_overlap_s", "MODEL/HLO",
           "roofline_frac"]
    lines = []
    if fmt == "md":
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    else:
        lines.append(",".join(hdr))
    for r in rows:
        vals = [r.arch, r.shape, r.mesh, f"{r.compute_s:.4f}",
                f"{r.memory_s:.4f}", f"{r.collective_s:.4f}", r.dominant,
                f"{r.t_overlap:.4f}", f"{r.useful_ratio:.2f}",
                f"{r.roofline_fraction:.3f}"]
        if fmt == "md":
            lines.append("| " + " | ".join(vals) + " |")
        else:
            lines.append(",".join(vals))
    return "\n".join(lines)


def main():
    rows = all_rooflines()
    print(table(rows, fmt="md"))
    # summary: hillclimb candidates
    trains = [r for r in rows if r.shape == "train_4k" and r.mesh == "pod"]
    if trains:
        worst = min(trains, key=lambda r: r.roofline_fraction)
        collb = max(rows, key=lambda r: r.collective_s /
                    max(r.t_overlap, 1e-12))
        print(f"\nworst roofline fraction: {worst.arch}×{worst.shape} "
              f"({worst.roofline_fraction:.3f})")
        print(f"most collective-bound:  {collb.arch}×{collb.shape} "
              f"({collb.collective_s:.4f}s of {collb.t_overlap:.4f}s)")


if __name__ == "__main__":
    main()
