"""Paper Figure 4: total execution time breakdown on a process failure.

CR uses file checkpointing, Reinit++/ULFM use buddy memory checkpointing
(Table 2 column for process failures)."""
from __future__ import annotations

from repro.sim import APPS, simulate_run

RANKS = [16, 64, 256, 1024]


def run(report=print):
    for app_key, app in APPS.items():
        for n in RANKS:
            for s in ["cr", "reinit", "ulfm"]:
                r = simulate_run(app, n, s, "process")
                report(
                    f"fig4_{app_key}_{s}_n{n},{r.total_s * 1e6:.0f},"
                    f"total={r.total_s:.2f};write={r.ckpt_write_s:.2f};"
                    f"mpi={r.mpi_recovery_s:.2f};app={r.app_time_s:.2f}")


if __name__ == "__main__":
    run()
