"""Table 2 + measured checkpoint costs at this machine's scale.

Times the REAL substrate on a ~64 MB train state, old path vs new path:

  old   np.savez shards + sha256-over-tobytes digests, single-threaded
        reads (the seed implementation, preserved under fmt="npz")
  new   serde frames + word-sum digests, parallel shard IO, memmapped
        verified reads (the fast-path engine)

The old-vs-new ratios are the paper's motivation made measurable: recovery
speed is won in the checkpoint substrate. `bench_file_io()` returns the
raw numbers so run.py can serialize them into BENCH_checkpoint.json and
recovery_time.py can fold them into end-to-end recovery figures.
"""
from __future__ import annotations

import tempfile
import time

import jax
import numpy as np

from repro.checkpoint import FileCheckpointer, checkpoint_kind_for

STATE_MB = 64.0
N_SHARDS = 4
DELTA_DIRTY_FRAC = 0.05         # steady-state dirtiness of the delta bench


def _state(mb: float = STATE_MB):
    n = int(mb * 1e6 / 4 / 4)
    key = jax.random.PRNGKey(0)
    return {f"p{i}": jax.random.normal(jax.random.fold_in(key, i), (n,))
            for i in range(4)}


def _time(fn, repeats: int = 3) -> float:
    """Best-of-N wall time — min is the standard noise-robust estimator
    for container CPU contention."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.monotonic()
        fn()
        best = min(best, time.monotonic() - t0)
    return best


def bench_file_io(state=None, *, mb: float = STATE_MB) -> dict:
    """Write/read timings for both formats on the same state. Loads run
    with verify=True — the digest check is part of the recovery path."""
    if state is None:
        state = _state(mb)
        jax.block_until_ready(state)
    out = {"state_mb": mb, "n_shards": N_SHARDS}

    # warmup: steady-state numbers, not one-time import/jit costs
    warm = _state(0.1)
    for fmt in ("npz", "bin"):
        with tempfile.TemporaryDirectory() as d, \
                FileCheckpointer(d, n_shards=N_SHARDS, fmt=fmt) as ck:
            ck.save(1, warm)
            ck.load_latest()

    for fmt in ("npz", "bin"):
        with tempfile.TemporaryDirectory() as d, \
                FileCheckpointer(d, keep=2, n_shards=N_SHARDS,
                                 fmt=fmt) as ck:
            out[f"{fmt}_write_s"] = _time(lambda: ck.save(1, state))
            out[f"{fmt}_async_submit_s"] = _time(
                lambda: ck.save(2, state, async_=True), repeats=1)
            ck.wait()
            loaded = {}

            def read():
                step, st = ck.load_latest()
                loaded["state"] = jax.tree.map(lambda a: a + 0, st)

            out[f"{fmt}_read_s"] = _time(read)

    out["write_speedup"] = out["npz_write_s"] / max(out["bin_write_s"], 1e-9)
    out["read_speedup"] = out["npz_read_s"] / max(out["bin_read_s"], 1e-9)
    out.update(bench_delta_io(mb=mb))
    return out


def bench_delta_io(*, mb: float = STATE_MB,
                   dirty_frac: float = DELTA_DIRTY_FRAC) -> dict:
    """Steady-state delta checkpointing on a `dirty_frac`-dirty state:
    every save mutates a contiguous `dirty_frac` window of each leaf (a
    different window each time, like an optimizer walking its state) and
    writes a tile-range delta against the previous save; reads compose
    base + deltas and verify the composed digests."""
    state = {k: np.array(v) for k, v in _state(mb).items()}
    out = {}
    with tempfile.TemporaryDirectory() as d, \
            FileCheckpointer(d, keep=16, n_shards=N_SHARDS,
                             delta_every=16) as ck:
        ck.save(1, state)
        full_bytes = ck.last_write["bytes"]
        counter = {"step": 1}

        def save_next():
            s = counter["step"] = counter["step"] + 1
            for v in state.values():
                n = v.size
                w = max(1, int(n * dirty_frac))
                start = (s * w) % max(1, n - w)
                v[start:start + w] += 1.0
            ck.save(s, state)

        out["bin_delta_write_s"] = _time(save_next)
        assert ck.last_write["kind"] == "delta", ck.last_write
        out["delta_bytes"] = ck.last_write["bytes"]
        out["delta_full_bytes"] = full_bytes
        out["delta_bytes_frac"] = ck.last_write["bytes"] / full_bytes
        out["delta_dirty_frac"] = dirty_frac
        loaded = {}

        def read():
            step, st = ck.load_latest()
            loaded["state"] = jax.tree.map(lambda a: a + 0, st)

        out["bin_delta_read_s"] = _time(read)
        # composed restore is bit-exact vs the live state
        step, st = ck.load_latest()
        assert all(np.array_equal(np.asarray(st[k]), state[k])
                   for k in state)
    return out


def run(report=print) -> dict:
    state = _state()
    jax.block_until_ready(state)
    io = bench_file_io(state)

    t0 = time.monotonic()
    mem_copy = jax.tree.map(lambda a: a + 0, state)
    jax.block_until_ready(mem_copy)
    t_mem = time.monotonic() - t0
    io["memory_copy_s"] = t_mem

    report(f"table2_file_write_sync_old,{io['npz_write_s'] * 1e6:.0f},64MB")
    report(f"table2_file_write_sync_new,{io['bin_write_s'] * 1e6:.0f},64MB")
    report(f"table2_file_write_async_submit,"
           f"{io['bin_async_submit_s'] * 1e6:.0f},64MB")
    report(f"table2_file_read_old,{io['npz_read_s'] * 1e6:.0f},64MB")
    report(f"table2_file_read_new,{io['bin_read_s'] * 1e6:.0f},64MB")
    report(f"table2_file_write_delta,{io['bin_delta_write_s'] * 1e6:.0f},"
           f"64MB_{io['delta_dirty_frac']:.0%}_dirty")
    report(f"table2_file_read_delta,{io['bin_delta_read_s'] * 1e6:.0f},"
           f"64MB_compose")
    report(f"table2_delta_bytes_frac,0,"
           f"frac={io['delta_bytes_frac']:.4f}")
    report(f"table2_memory_copy,{t_mem * 1e6:.0f},64MB")
    report(f"table2_write_speedup_new_vs_old,0,"
           f"x={io['write_speedup']:.2f}")
    report(f"table2_read_speedup_new_vs_old,0,"
           f"x={io['read_speedup']:.2f}")
    report(f"table2_mem_speedup_vs_file,0,"
           f"x={io['bin_write_s'] / max(t_mem, 1e-9):.1f}")
    for failure in ["process", "node"]:
        for strat in ["cr", "ulfm", "reinit"]:
            report(f"table2_kind_{failure}_{strat},0,"
                   f"{checkpoint_kind_for(failure, strat)}")
    return io


if __name__ == "__main__":
    run()
