"""Table 2 + measured checkpoint costs at this machine's scale.

Times the REAL substrate on a ~64 MB train state, old path vs new path:

  old   np.savez shards + sha256-over-tobytes digests, single-threaded
        reads (the seed implementation, preserved under fmt="npz")
  new   serde frames + word-sum digests, parallel shard IO, memmapped
        verified reads (the fast-path engine)

The old-vs-new ratios are the paper's motivation made measurable: recovery
speed is won in the checkpoint substrate. `bench_file_io()` returns the
raw numbers so run.py can serialize them into BENCH_checkpoint.json and
recovery_time.py can fold them into end-to-end recovery figures.
"""
from __future__ import annotations

import tempfile
import time

import jax
import numpy as np

from repro.checkpoint import FileCheckpointer, checkpoint_kind_for

STATE_MB = 64.0
N_SHARDS = 4
DELTA_DIRTY_FRAC = 0.05         # steady-state dirtiness of the delta bench


def _state(mb: float = STATE_MB):
    n = int(mb * 1e6 / 4 / 4)
    key = jax.random.PRNGKey(0)
    return {f"p{i}": jax.random.normal(jax.random.fold_in(key, i), (n,))
            for i in range(4)}


def _time(fn, repeats: int = 3) -> float:
    """Best-of-N wall time — min is the standard noise-robust estimator
    for container CPU contention."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.monotonic()
        fn()
        best = min(best, time.monotonic() - t0)
    return best


def bench_file_io(state=None, *, mb: float = STATE_MB) -> dict:
    """Write/read timings for both formats on the same state. Loads run
    with verify=True — the digest check is part of the recovery path."""
    if state is None:
        state = _state(mb)
        jax.block_until_ready(state)
    out = {"state_mb": mb, "n_shards": N_SHARDS}

    # warmup: steady-state numbers, not one-time import/jit costs
    warm = _state(0.1)
    for fmt in ("npz", "bin"):
        with tempfile.TemporaryDirectory() as d, \
                FileCheckpointer(d, n_shards=N_SHARDS, fmt=fmt) as ck:
            ck.save(1, warm)
            ck.load_latest()

    for fmt in ("npz", "bin"):
        with tempfile.TemporaryDirectory() as d, \
                FileCheckpointer(d, keep=2, n_shards=N_SHARDS,
                                 fmt=fmt) as ck:
            out[f"{fmt}_write_s"] = _time(lambda: ck.save(1, state))
            out[f"{fmt}_async_submit_s"] = _time(
                lambda: ck.save(2, state, async_=True), repeats=1)
            ck.wait()
            loaded = {}

            def read():
                step, st = ck.load_latest()
                loaded["state"] = jax.tree.map(lambda a: a + 0, st)

            out[f"{fmt}_read_s"] = _time(read)

    out["write_speedup"] = out["npz_write_s"] / max(out["bin_write_s"], 1e-9)
    out["read_speedup"] = out["npz_read_s"] / max(out["bin_read_s"], 1e-9)
    out.update(bench_delta_io(mb=mb))
    return out


def _mutate(state: dict, step: int, dirty_frac: float) -> dict:
    """Dirty a contiguous `dirty_frac` window of each leaf on device (a
    different window each step, like an optimizer walking its state)."""
    out = {}
    for k, v in state.items():
        n = v.size
        w = max(1, int(n * dirty_frac))
        start = (step * w) % max(1, n - w)
        out[k] = v.at[start:start + w].add(1.0)
    jax.block_until_ready(out)
    return out


def bench_delta_io(*, mb: float = STATE_MB,
                   dirty_frac: float = DELTA_DIRTY_FRAC) -> dict:
    """Steady-state delta checkpointing on a `dirty_frac`-dirty device
    state with the dirty-tile gather on: every save writes a tile-range
    delta against the previous save, and only the gathered dirty tiles
    (plus 12 B/tile of digest rows) cross device→host; reads compose
    base + deltas and verify the composed digests. `delta_d2h_frac` is
    the headline: transferred bytes as a fraction of a full-state drain."""
    state = _state(mb)
    jax.block_until_ready(state)
    out = {}
    with tempfile.TemporaryDirectory() as d, \
            FileCheckpointer(d, keep=16, n_shards=N_SHARDS,
                             delta_every=16, gather="on") as ck:
        ck.save(1, state)
        full_bytes = ck.last_write["bytes"]
        full_d2h = ck.last_write["d2h_bytes"]
        box = {"step": 1, "state": state}

        def save_next():
            s = box["step"] = box["step"] + 1
            box["state"] = _mutate(box["state"], s, dirty_frac)
            ck.save(s, box["state"])

        out["bin_delta_write_s"] = _time(save_next)
        assert ck.last_write["kind"] == "delta", ck.last_write
        out["delta_bytes"] = ck.last_write["bytes"]
        out["delta_full_bytes"] = full_bytes
        out["delta_bytes_frac"] = ck.last_write["bytes"] / full_bytes
        out["delta_dirty_frac"] = dirty_frac
        # D2H traffic proportional to dirt: the gather path's whole point
        out["delta_d2h_bytes"] = ck.last_write["d2h_bytes"]
        out["delta_full_d2h_bytes"] = full_d2h
        out["delta_d2h_frac"] = ck.last_write["d2h_bytes"] / max(full_d2h, 1)
        loaded = {}

        def read():
            step, st = ck.load_latest()
            loaded["state"] = jax.tree.map(lambda a: a + 0, st)

        out["bin_delta_read_s"] = _time(read)
        # composed restore is bit-exact vs the live state
        step, st = ck.load_latest()
        assert all(np.array_equal(np.asarray(st[k]),
                                  np.asarray(box["state"][k]))
                   for k in state)
    return out


def bench_rebase(*, mb: float = 16.0,
                 dirty_frac: float = DELTA_DIRTY_FRAC,
                 links: int = 8) -> dict:
    """Restore cost of a `links`-long delta chain before vs after the
    background re-base compacts it into a self-contained base. The
    rebased restore must be bit-identical to the chained one."""
    state = _state(mb)
    jax.block_until_ready(state)
    out = {"rebase_state_mb": mb, "rebase_chain_links": links}
    with tempfile.TemporaryDirectory() as d, \
            FileCheckpointer(d, keep=links + 4, n_shards=N_SHARDS,
                             delta_every=links + 4, gather="on") as ck:
        ck.save(1, state)
        for s in range(2, links + 2):
            state = _mutate(state, s, dirty_frac)
            ck.save(s, state)
        loaded = {}

        def read():
            step, st = ck.load_latest()
            loaded["state"] = {k: np.asarray(v) for k, v in st.items()}

        out["chained_read_s"] = _time(read)
        # arm the threshold; the next delta save trips the compaction
        ck.rebase_after = 1
        state = _mutate(state, links + 2, dirty_frac)
        ck.save(links + 2, state)
        ck.wait()
        assert ck.last_rebase.get("ok"), ck.last_rebase
        out["rebased_read_s"] = _time(read)
        assert all(np.array_equal(loaded["state"][k],
                                  np.asarray(state[k])) for k in state)
        out["rebase_read_speedup"] = out["chained_read_s"] \
            / max(out["rebased_read_s"], 1e-9)
    return out


def run(report=print) -> dict:
    state = _state()
    jax.block_until_ready(state)
    io = bench_file_io(state)
    io.update(bench_rebase())

    t0 = time.monotonic()
    mem_copy = jax.tree.map(lambda a: a + 0, state)
    jax.block_until_ready(mem_copy)
    t_mem = time.monotonic() - t0
    io["memory_copy_s"] = t_mem

    report(f"table2_file_write_sync_old,{io['npz_write_s'] * 1e6:.0f},64MB")
    report(f"table2_file_write_sync_new,{io['bin_write_s'] * 1e6:.0f},64MB")
    report(f"table2_file_write_async_submit,"
           f"{io['bin_async_submit_s'] * 1e6:.0f},64MB")
    report(f"table2_file_read_old,{io['npz_read_s'] * 1e6:.0f},64MB")
    report(f"table2_file_read_new,{io['bin_read_s'] * 1e6:.0f},64MB")
    report(f"table2_file_write_delta,{io['bin_delta_write_s'] * 1e6:.0f},"
           f"64MB_{io['delta_dirty_frac']:.0%}_dirty")
    report(f"table2_file_read_delta,{io['bin_delta_read_s'] * 1e6:.0f},"
           f"64MB_compose")
    report(f"table2_delta_bytes_frac,0,"
           f"frac={io['delta_bytes_frac']:.4f}")
    report(f"table2_delta_d2h_frac,0,"
           f"frac={io['delta_d2h_frac']:.4f}")
    report(f"table2_rebase_chained_read,{io['chained_read_s'] * 1e6:.0f},"
           f"{io['rebase_state_mb']:.0f}MB_{io['rebase_chain_links']}links")
    report(f"table2_rebase_rebased_read,{io['rebased_read_s'] * 1e6:.0f},"
           f"{io['rebase_state_mb']:.0f}MB_base")
    report(f"table2_rebase_read_speedup,0,"
           f"x={io['rebase_read_speedup']:.2f}")
    report(f"table2_memory_copy,{t_mem * 1e6:.0f},64MB")
    report(f"table2_write_speedup_new_vs_old,0,"
           f"x={io['write_speedup']:.2f}")
    report(f"table2_read_speedup_new_vs_old,0,"
           f"x={io['read_speedup']:.2f}")
    report(f"table2_mem_speedup_vs_file,0,"
           f"x={io['bin_write_s'] / max(t_mem, 1e-9):.1f}")
    for failure in ["process", "node"]:
        for strat in ["cr", "ulfm", "reinit"]:
            report(f"table2_kind_{failure}_{strat},0,"
                   f"{checkpoint_kind_for(failure, strat)}")
    return io


if __name__ == "__main__":
    run()
