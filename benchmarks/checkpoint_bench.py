"""Table 2 + measured checkpoint costs at this machine's scale.

Times the REAL substrate: sharded file checkpoints (write+read, sync and
async) vs the in-memory buddy copy, on a ~64 MB train state — the ratio is
the paper's motivation for memory checkpointing."""
from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import FileCheckpointer, checkpoint_kind_for


def _state(mb: float = 64.0):
    n = int(mb * 1e6 / 4 / 4)
    key = jax.random.PRNGKey(0)
    return {f"p{i}": jax.random.normal(jax.random.fold_in(key, i), (n,))
            for i in range(4)}


def run(report=print):
    state = _state()
    jax.block_until_ready(state)

    with tempfile.TemporaryDirectory() as d:
        ck = FileCheckpointer(d, keep=2, n_shards=2)
        t0 = time.monotonic()
        ck.save(1, state)
        t_file_sync = time.monotonic() - t0
        t0 = time.monotonic()
        ck.save(2, state, async_=True)
        t_file_async_submit = time.monotonic() - t0
        ck.wait()
        t0 = time.monotonic()
        _, loaded = ck.load_latest()
        t_file_read = time.monotonic() - t0

    t0 = time.monotonic()
    mem_copy = jax.tree.map(lambda a: a + 0, state)
    jax.block_until_ready(mem_copy)
    t_mem = time.monotonic() - t0

    report(f"table2_file_write_sync,{t_file_sync * 1e6:.0f},64MB")
    report(f"table2_file_write_async_submit,"
           f"{t_file_async_submit * 1e6:.0f},64MB")
    report(f"table2_file_read,{t_file_read * 1e6:.0f},64MB")
    report(f"table2_memory_copy,{t_mem * 1e6:.0f},64MB")
    report(f"table2_mem_speedup_vs_file,0,"
           f"x={t_file_sync / max(t_mem, 1e-9):.1f}")
    for failure in ["process", "node"]:
        for strat in ["cr", "ulfm", "reinit"]:
            report(f"table2_kind_{failure}_{strat},0,"
                   f"{checkpoint_kind_for(failure, strat)}")


if __name__ == "__main__":
    run()
