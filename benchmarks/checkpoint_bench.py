"""Table 2 + measured checkpoint costs at this machine's scale.

Times the REAL substrate on a ~64 MB train state, old path vs new path:

  old   np.savez shards + sha256-over-tobytes digests, single-threaded
        reads (the seed implementation, preserved under fmt="npz")
  new   serde frames + word-sum digests, parallel shard IO, memmapped
        verified reads (the fast-path engine)

The old-vs-new ratios are the paper's motivation made measurable: recovery
speed is won in the checkpoint substrate. `bench_file_io()` returns the
raw numbers so run.py can serialize them into BENCH_checkpoint.json and
recovery_time.py can fold them into end-to-end recovery figures.
"""
from __future__ import annotations

import tempfile
import time

import jax

from repro.checkpoint import FileCheckpointer, checkpoint_kind_for

STATE_MB = 64.0
N_SHARDS = 4


def _state(mb: float = STATE_MB):
    n = int(mb * 1e6 / 4 / 4)
    key = jax.random.PRNGKey(0)
    return {f"p{i}": jax.random.normal(jax.random.fold_in(key, i), (n,))
            for i in range(4)}


def _time(fn, repeats: int = 3) -> float:
    """Best-of-N wall time — min is the standard noise-robust estimator
    for container CPU contention."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.monotonic()
        fn()
        best = min(best, time.monotonic() - t0)
    return best


def bench_file_io(state=None, *, mb: float = STATE_MB) -> dict:
    """Write/read timings for both formats on the same state. Loads run
    with verify=True — the digest check is part of the recovery path."""
    if state is None:
        state = _state(mb)
        jax.block_until_ready(state)
    out = {"state_mb": mb, "n_shards": N_SHARDS}

    # warmup: steady-state numbers, not one-time import/jit costs
    warm = _state(0.1)
    for fmt in ("npz", "bin"):
        with tempfile.TemporaryDirectory() as d, \
                FileCheckpointer(d, n_shards=N_SHARDS, fmt=fmt) as ck:
            ck.save(1, warm)
            ck.load_latest()

    for fmt in ("npz", "bin"):
        with tempfile.TemporaryDirectory() as d, \
                FileCheckpointer(d, keep=2, n_shards=N_SHARDS,
                                 fmt=fmt) as ck:
            out[f"{fmt}_write_s"] = _time(lambda: ck.save(1, state))
            out[f"{fmt}_async_submit_s"] = _time(
                lambda: ck.save(2, state, async_=True), repeats=1)
            ck.wait()
            loaded = {}

            def read():
                step, st = ck.load_latest()
                loaded["state"] = jax.tree.map(lambda a: a + 0, st)

            out[f"{fmt}_read_s"] = _time(read)

    out["write_speedup"] = out["npz_write_s"] / max(out["bin_write_s"], 1e-9)
    out["read_speedup"] = out["npz_read_s"] / max(out["bin_read_s"], 1e-9)
    return out


def run(report=print) -> dict:
    state = _state()
    jax.block_until_ready(state)
    io = bench_file_io(state)

    t0 = time.monotonic()
    mem_copy = jax.tree.map(lambda a: a + 0, state)
    jax.block_until_ready(mem_copy)
    t_mem = time.monotonic() - t0
    io["memory_copy_s"] = t_mem

    report(f"table2_file_write_sync_old,{io['npz_write_s'] * 1e6:.0f},64MB")
    report(f"table2_file_write_sync_new,{io['bin_write_s'] * 1e6:.0f},64MB")
    report(f"table2_file_write_async_submit,"
           f"{io['bin_async_submit_s'] * 1e6:.0f},64MB")
    report(f"table2_file_read_old,{io['npz_read_s'] * 1e6:.0f},64MB")
    report(f"table2_file_read_new,{io['bin_read_s'] * 1e6:.0f},64MB")
    report(f"table2_memory_copy,{t_mem * 1e6:.0f},64MB")
    report(f"table2_write_speedup_new_vs_old,0,"
           f"x={io['write_speedup']:.2f}")
    report(f"table2_read_speedup_new_vs_old,0,"
           f"x={io['read_speedup']:.2f}")
    report(f"table2_mem_speedup_vs_file,0,"
           f"x={io['bin_write_s'] / max(t_mem, 1e-9):.1f}")
    for failure in ["process", "node"]:
        for strat in ["cr", "ulfm", "reinit"]:
            report(f"table2_kind_{failure}_{strat},0,"
                   f"{checkpoint_kind_for(failure, strat)}")
    return io


if __name__ == "__main__":
    run()
