"""§5.3/§5.4 ground truth: recovery measured on the real-process runtime.

Deploys the actual root/daemon/worker tree on this host, SIGKILLs a rank
(or a node), and reports the measured recovery phases. This grounds the
simulator's constants: Reinit++ process recovery lands near the paper's
≈0.5 s because process spawn + rejoin THERE is what it is HERE too.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def _one(mode: str, kind: str, tmp: str) -> dict:
    env = dict(os.environ, PYTHONPATH=SRC)
    report = os.path.join(tmp, f"{mode}_{kind}.json")
    ckpt = os.path.join(tmp, f"ck_{mode}_{kind}")
    os.makedirs(ckpt, exist_ok=True)
    cmd = [sys.executable, "-m", "repro.runtime.root",
           "--nodes", "2", "--ranks-per-node", "2", "--spares", "1",
           "--steps", "6", "--dim", "256", "--ckpt-dir", ckpt,
           "--mode", mode, "--fail-step", "3", "--fail-rank", "1",
           "--fail-kind", kind, "--report", report]
    subprocess.run(cmd, env=env, capture_output=True, timeout=120,
                   check=True)
    with open(report) as f:
        return json.load(f)


def run(report=print):
    with tempfile.TemporaryDirectory() as tmp:
        results = {}
        for mode in ["reinit", "cr"]:
            for kind in ["process", "node"]:
                rep = _one(mode, kind, tmp)
                ev = rep["events"][-1]
                t = ev["mpi_recovery_s"]
                results[(mode, kind)] = t
                report(f"runtime_{mode}_{kind},{t * 1e6:.0f},"
                       f"recovery_s={t:.3f}")
        for kind in ["process", "node"]:
            r = results[("cr", kind)] / results[("reinit", kind)]
            report(f"runtime_ratio_cr_over_reinit_{kind},0,x={r:.2f}")


if __name__ == "__main__":
    run()
