"""§5.3/§5.4 ground truth: recovery measured on the real-process runtime.

Deploys the actual root/daemon/worker tree on this host, SIGKILLs a rank
(or a node), and reports the measured recovery phases. This grounds the
simulator's constants: Reinit++ process recovery lands near the paper's
≈0.5 s because process spawn + rejoin THERE is what it is HERE too.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def _one(mode: str, kind: str, tmp: str) -> dict:
    env = dict(os.environ, PYTHONPATH=SRC)
    report = os.path.join(tmp, f"{mode}_{kind}.json")
    ckpt = os.path.join(tmp, f"ck_{mode}_{kind}")
    os.makedirs(ckpt, exist_ok=True)
    cmd = [sys.executable, "-m", "repro.runtime.root",
           "--nodes", "2", "--ranks-per-node", "2", "--spares", "1",
           "--steps", "6", "--dim", "256", "--ckpt-dir", ckpt,
           "--mode", mode, "--fail-step", "3", "--fail-rank", "1",
           "--fail-kind", kind, "--report", report]
    subprocess.run(cmd, env=env, capture_output=True, timeout=120,
                   check=True)
    with open(report) as f:
        return json.load(f)


def bench_buddy_spill(report=print, *, n_steps: int = 24,
                      payload_kb: int = 256, retain: int = 8,
                      hot_steps: int = 2) -> dict:
    """BuddyStore memory/file split under retention pressure (ROADMAP
    item): a wide retention window with a small hot set forces the LRU
    tier to spill, and the counters report where the bytes live.

    Returns {spilled_bytes, resident_bytes, spill_frac} and prints the
    usual CSV rows."""
    if SRC not in sys.path:
        sys.path.insert(0, SRC)
    from repro.checkpoint.memory_ckpt import BuddyStore

    payload = os.urandom(payload_kb * 1024)
    with tempfile.TemporaryDirectory() as spill:
        store = BuddyStore(0, 4, retain=retain,
                           spill_dir=spill, hot_steps=hot_steps)
        for step in range(1, n_steps + 1):
            store.save(step, payload)
            store.hold(3, step, payload)      # buddy pushes held for rank 3
        spilled = store.spilled_bytes
        resident = store.resident_bytes()
        total = spilled + resident
        frac = spilled / total if total else 0.0
        report(f"buddy_spilled_bytes,{spilled},retain={retain}_"
               f"hot={hot_steps}")
        report(f"buddy_resident_bytes,{resident},"
               f"spill_frac={frac:.2f}")
    return {"spilled_bytes": spilled, "resident_bytes": resident,
            "spill_frac": frac}


def bench_detection_latency(report=print, *, stall_timeout_s: float = 3.0,
                            hb_period_s: float = 0.2,
                            hb_timeout_s: float = 1.0) -> dict:
    """Hang-detection latency, measured on the live process tree: the same
    silent-rank fault detected by (a) the root's stall watchdog and (b)
    the worker neighbour-heartbeat ring. The root clocks each from the
    stuck barrier's first arrival to the kill order — the number the sim's
    detection constants are calibrated against."""
    if SRC not in sys.path:
        sys.path.insert(0, SRC)
    from repro.scenarios import Fault, Scenario, Topology
    from repro.scenarios.engine import run_real

    topo = Topology(nodes=2, ranks_per_node=2, spares=1)
    fault = (Fault("rank", 1, 3, how="hang"),)
    cells = {
        "watchdog": Scenario(
            name="detect-watchdog", topology=topo, steps=6, dim=64,
            faults=fault, stall_timeout_s=stall_timeout_s,
            strategies=("reinit",)),
        "heartbeat": Scenario(
            name="detect-heartbeat", topology=topo, steps=6, dim=64,
            faults=fault, heartbeat_period_s=hb_period_s,
            heartbeat_timeout_s=hb_timeout_s, strategies=("reinit",)),
    }
    out = {}
    with tempfile.TemporaryDirectory() as tmp:
        for name, sc in cells.items():
            res = run_real(sc, "reinit", os.path.join(tmp, name),
                           timeout=180)
            ev = res.detail["events"][-1]
            assert ev["detected_by"] == name, ev
            t = ev["detect_latency_s"]
            out[name] = t
            report(f"detect_{name},{t * 1e6:.0f},latency_s={t:.3f}")
    ratio = out["watchdog"] / out["heartbeat"]
    report(f"detect_ratio_watchdog_over_heartbeat,0,x={ratio:.2f}")
    return out


def bench_growback(report=print) -> dict:
    """Shrink -> grow end-to-end on the live process tree: the
    `shrink-then-growback` cell measured from the node loss to the
    grow's consensus release. Reports the two recovery times and the
    whole-lifecycle wall clock; the growback number lands in
    BENCH_checkpoint.json behind the --check-regression gate."""
    if SRC not in sys.path:
        sys.path.insert(0, SRC)
    from repro.scenarios.catalog import get_scenario
    from repro.scenarios.engine import run_real

    sc = get_scenario("shrink-then-growback")
    with tempfile.TemporaryDirectory() as tmp:
        res = run_real(sc, "shrink", tmp, timeout=180)
    events = res.detail["events"]
    shrink_ev = next(ev for ev in events if ev.get("shrink"))
    grow_ev = next(ev for ev in events if ev.get("grow"))
    shrink_s = shrink_ev.get("mpi_recovery_s", 0.0)
    # grow e2e: REJOIN admission -> GROW broadcast -> re-admitted ranks
    # respawned/registered -> consensus released
    grow_s = grow_ev.get("mpi_recovery_s", 0.0)
    e2e = grow_ev.get("join_release_s", grow_s)
    out = {"shrink_s": shrink_s, "grow_s": grow_s, "growback_e2e_s": e2e,
           "world_restored": grow_ev.get("world_after")}
    report(f"growback_shrink,{shrink_s * 1e6:.0f},recovery_s={shrink_s:.3f}")
    report(f"growback_grow,{grow_s * 1e6:.0f},recovery_s={grow_s:.3f}")
    report(f"growback_e2e,{e2e * 1e6:.0f},"
           f"world_restored={out['world_restored']}")
    return out


def bench_rehost(report=print) -> dict:
    """Gray-failure mitigation end-to-end on the live process tree: the
    `slow-node-drain-growback` cell measured from the sustained slowdown
    to the repaired node's grow-back consensus. Reports the straggler
    detection latency (first withheld barrier -> drain order), the
    shrink and grow recovery times, the whole-lifecycle wall clock, and
    the cost model's tolerate-vs-rehost verdict for the same shape —
    time-to-rehost is the price the oracle weighs against the per-step
    throughput lost to tolerating. The e2e number lands in
    BENCH_checkpoint.json behind the --check-regression gate."""
    if SRC not in sys.path:
        sys.path.insert(0, SRC)
    from repro.scenarios.catalog import get_scenario
    from repro.scenarios.engine import run_real
    from repro.sim import APPS, rehost_break_even

    sc = get_scenario("slow-node-drain-growback")
    with tempfile.TemporaryDirectory() as tmp:
        res = run_real(sc, "shrink", tmp, timeout=180)
    events = res.detail["events"]
    drain_ev = next(ev for ev in events
                    if ev.get("detected_by") == "straggler")
    grow_ev = next(ev for ev in events if ev.get("grow"))
    detect_s = drain_ev.get("detect_latency_s", 0.0)
    shrink_s = drain_ev.get("mpi_recovery_s", 0.0)
    grow_s = grow_ev.get("mpi_recovery_s", 0.0)
    e2e = detect_s + shrink_s \
        + grow_ev.get("join_release_s", grow_s)
    oracle = rehost_break_even(APPS["comd"], 64, slow_factor=6.0,
                               repair_after=4)
    out = {"detect_s": detect_s, "shrink_s": shrink_s, "grow_s": grow_s,
           "e2e_s": e2e, "world_restored": grow_ev.get("world_after"),
           "break_even_factor": oracle["break_even_factor"]}
    report(f"rehost_detect,{detect_s * 1e6:.0f},latency_s={detect_s:.3f}")
    report(f"rehost_shrink,{shrink_s * 1e6:.0f},recovery_s={shrink_s:.3f}")
    report(f"rehost_grow,{grow_s * 1e6:.0f},recovery_s={grow_s:.3f}")
    report(f"rehost_e2e,{e2e * 1e6:.0f},"
           f"world_restored={out['world_restored']}")
    report(f"rehost_break_even_factor,0,"
           f"x={oracle['break_even_factor']:.3f}")
    return out


def bench_failover(report=print, *, sizes=((2, 2), (2, 4))) -> dict:
    """Zero-rollback replica failover vs Reinit++ global restart, on the
    live process tree, at growing rank counts. The same fenced rank kill
    is recovered both ways; e2e is detection -> the world computing again:
    for replica that is `promote_complete_s` (the promoted shadow's
    arrival releases the stalled barrier), for reinit `join_release_s`
    (respawn + re-register + rollback consensus — conservatively
    EXCLUDING the recomputed steps reinit still owes afterwards)."""
    if SRC not in sys.path:
        sys.path.insert(0, SRC)
    from repro.scenarios import Fault, Scenario, Topology
    from repro.scenarios.engine import run_real

    out = {"sizes": {}}
    for nodes, rpn in sizes:
        ranks = nodes * rpn
        sc = Scenario(
            name=f"failover-{ranks}r",
            topology=Topology(nodes=nodes, ranks_per_node=rpn, spares=1),
            steps=8, dim=128, faults=(Fault("rank", 1, 4),),
            strategies=("replica", "reinit"))
        with tempfile.TemporaryDirectory() as tmp:
            rep = run_real(sc, "replica", os.path.join(tmp, "replica"),
                           timeout=180)
            rei = run_real(sc, "reinit", os.path.join(tmp, "reinit"),
                           timeout=180)
        rep_ev = rep.detail["events"][-1]
        rei_ev = rei.detail["events"][-1]
        assert rep_ev.get("promote"), rep_ev
        assert rep.resume_consistent and rei.resume_consistent
        rep_e2e = rep_ev["promote_complete_s"]
        rei_e2e = rei_ev.get("join_release_s",
                             rei_ev.get("mpi_recovery_s", 0.0))
        speedup = rei_e2e / rep_e2e if rep_e2e else float("inf")
        out["sizes"][str(ranks)] = {
            "replica_e2e_s": rep_e2e, "reinit_e2e_s": rei_e2e,
            "speedup": speedup}
        report(f"failover_replica_{ranks}r,{rep_e2e * 1e6:.0f},"
               f"e2e_s={rep_e2e:.4f}")
        report(f"failover_reinit_{ranks}r,{rei_e2e * 1e6:.0f},"
               f"e2e_s={rei_e2e:.4f}")
        report(f"failover_speedup_{ranks}r,0,x={speedup:.1f}")
    largest = max(out["sizes"], key=int)
    out["largest_ranks"] = int(largest)
    out.update(out["sizes"][largest])
    return out


def run(report=print, growback=True, failover=True):
    bench_buddy_spill(report)
    bench_detection_latency(report)
    if growback:       # run.py measures it separately for the bench json
        bench_growback(report)
    if failover:       # likewise measured separately for the bench json
        bench_failover(report)
    with tempfile.TemporaryDirectory() as tmp:
        results = {}
        for mode in ["reinit", "cr"]:
            for kind in ["process", "node"]:
                rep = _one(mode, kind, tmp)
                ev = rep["events"][-1]
                t = ev["mpi_recovery_s"]
                results[(mode, kind)] = t
                report(f"runtime_{mode}_{kind},{t * 1e6:.0f},"
                       f"recovery_s={t:.3f}")
        for kind in ["process", "node"]:
            r = results[("cr", kind)] / results[("reinit", kind)]
            report(f"runtime_ratio_cr_over_reinit_{kind},0,x={r:.2f}")


if __name__ == "__main__":
    run()
