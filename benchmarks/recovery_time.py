"""Paper Figures 6 & 7: MPI recovery time vs rank count.

Simulated at 16–1024 ranks (calibrated protocol simulation, sim/), with
the real-process runtime's measured numbers (runtime_bench.py) grounding
the small-scale end.

Also reports *end-to-end* recovery (detect + MPI recovery + checkpoint
restore) with the restore term measured on the real substrate:

  old   the serialized global-restart engine this repo started from —
        polling detection/drain sleeps, teardown + re-deploy, then a
        full-state np.savez read-back, each phase strictly after the
        previous one.
  new   the pipelined Reinit++ engine: event-driven detection, REINIT
        tree broadcast with parallel respawn, and the state
        redistribution (delta-frame compose from memmapped shards)
        overlapped with the respawn — the paper's headline term.
"""
from __future__ import annotations

from repro.sim import recovery_e2e, recovery_time

RANKS = [16, 32, 64, 128, 256, 512, 1024]
E2E_RANKS = 64


def e2e_rows(ckpt_io: dict | None = None) -> dict:
    """End-to-end recovery at E2E_RANKS ranks for a process failure:
    serialized CR engine + full npz restore (old) vs pipelined Reinit++
    engine + delta-frame restore (new), restore terms measured."""
    if ckpt_io is None:
        from benchmarks.checkpoint_bench import bench_file_io
        ckpt_io = bench_file_io()
    read_old = ckpt_io["npz_read_s"]
    read_new = ckpt_io.get("bin_delta_read_s", ckpt_io["bin_read_s"])
    old = recovery_e2e("cr", E2E_RANKS, "process", read_old,
                       pipelined=False)
    new = recovery_e2e("reinit", E2E_RANKS, "process", read_new,
                       pipelined=True)
    return {"ranks": E2E_RANKS,
            "detect_old_s": old["detect_s"],
            "detect_new_s": new["detect_s"],
            "mpi_old_s": old["mpi_recovery_s"],
            "mpi_new_s": new["mpi_recovery_s"],
            "read_old_s": read_old, "read_new_s": read_new,
            "recovery_e2e_old_s": old["total_s"],
            "recovery_e2e_new_s": new["total_s"],
            "recovery_speedup": old["total_s"] / max(new["total_s"],
                                                     1e-9)}


def rows(failure_kind: str):
    strategies = ["cr", "reinit"] if failure_kind == "node" \
        else ["cr", "reinit", "ulfm"]
    out = []
    for n in RANKS:
        row = {"ranks": n}
        for s in strategies:
            r = recovery_time(s, n, failure_kind)
            row[s] = r["mpi_recovery_s"]
            row[f"{s}_detect"] = r["detect_s"]
        out.append(row)
    return out


def run(report=print, ckpt_io: dict | None = None):
    for kind in ["process", "node"]:
        fig = "fig6" if kind == "process" else "fig7"
        for row in rows(kind):
            n = row["ranks"]
            for s in ("cr", "reinit", "ulfm"):
                if s in row:
                    report(f"{fig}_{kind}_{s}_n{n},"
                           f"{row[s] * 1e6:.0f},"
                           f"recovery_s={row[s]:.3f}")
    # headline ratios
    p = rows("process")
    report(f"fig6_ratio_cr_over_reinit_1024,0,"
           f"ratio={p[-1]['cr'] / p[-1]['reinit']:.2f}")
    report(f"fig6_ratio_ulfm_over_reinit_1024,0,"
           f"ratio={p[-1]['ulfm'] / p[-1]['reinit']:.2f}")
    nn = rows("node")
    report(f"fig7_ratio_cr_over_reinit_1024,0,"
           f"ratio={nn[-1]['cr'] / nn[-1]['reinit']:.2f}")
    # measured end-to-end recovery: serialized full-restore engine vs
    # pipelined delta-restore engine
    e2e = e2e_rows(ckpt_io)
    report(f"recovery_e2e_old_n{e2e['ranks']},"
           f"{e2e['recovery_e2e_old_s'] * 1e6:.0f},serialized+npz_restore")
    report(f"recovery_e2e_new_n{e2e['ranks']},"
           f"{e2e['recovery_e2e_new_s'] * 1e6:.0f},pipelined+delta_restore")
    report(f"recovery_e2e_speedup,0,x={e2e['recovery_speedup']:.2f}")
    return e2e


if __name__ == "__main__":
    run()
