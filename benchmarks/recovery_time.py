"""Paper Figures 6 & 7: MPI recovery time vs rank count.

Simulated at 16–1024 ranks (calibrated protocol simulation, sim/), with
the real-process runtime's measured numbers (runtime_bench.py) grounding
the small-scale end.
"""
from __future__ import annotations

from repro.sim import recovery_time

RANKS = [16, 32, 64, 128, 256, 512, 1024]


def rows(failure_kind: str):
    strategies = ["cr", "reinit"] if failure_kind == "node" \
        else ["cr", "reinit", "ulfm"]
    out = []
    for n in RANKS:
        row = {"ranks": n}
        for s in strategies:
            r = recovery_time(s, n, failure_kind)
            row[s] = r["mpi_recovery_s"]
            row[f"{s}_detect"] = r["detect_s"]
        out.append(row)
    return out


def run(report=print):
    for kind in ["process", "node"]:
        fig = "fig6" if kind == "process" else "fig7"
        for row in rows(kind):
            n = row["ranks"]
            for s in ("cr", "reinit", "ulfm"):
                if s in row:
                    report(f"{fig}_{kind}_{s}_n{n},"
                           f"{row[s] * 1e6:.0f},"
                           f"recovery_s={row[s]:.3f}")
    # headline ratios
    p = rows("process")
    report(f"fig6_ratio_cr_over_reinit_1024,0,"
           f"ratio={p[-1]['cr'] / p[-1]['reinit']:.2f}")
    report(f"fig6_ratio_ulfm_over_reinit_1024,0,"
           f"ratio={p[-1]['ulfm'] / p[-1]['reinit']:.2f}")
    nn = rows("node")
    report(f"fig7_ratio_cr_over_reinit_1024,0,"
           f"ratio={nn[-1]['cr'] / nn[-1]['reinit']:.2f}")


if __name__ == "__main__":
    run()
