"""CPU wall-time microbenchmarks of the jitted step functions (regression
guard — real perf numbers come from the dry-run roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models.model import Model
from repro.train import AdamWConfig, TokenPipeline
from repro.train.optimizer import adamw_init, adamw_update


def _time(fn, *args, iters=5):
    fn(*args)                      # compile + warm
    t0 = time.monotonic()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / iters


def run(report=print):
    cfg = reduced(get_config("paper-demo"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = TokenPipeline(cfg.vocab_size, 4, 64, seed=0)
    batch = data.batch(0)
    opt_cfg = AdamWConfig()
    opt = adamw_init(params)

    @jax.jit
    def train_step(p, o, b):
        (loss, _), g = jax.value_and_grad(model.loss_fn, has_aux=True)(p, b)
        return adamw_update(p, g, o, opt_cfg)[0], loss

    t = _time(train_step, params, opt, batch)
    report(f"step_train_paper_demo,{t * 1e6:.0f},B4xS64")

    logits, state = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=96))(
            params, {"tokens": batch["tokens"]})
    dec = jax.jit(model.decode_step)
    tok = jnp.zeros((4, 1), jnp.int32)
    t = _time(lambda: dec(params, tok, state, jnp.int32(64)))
    report(f"step_decode_paper_demo,{t * 1e6:.0f},B4_cache96")


if __name__ == "__main__":
    run()
