"""In-JAX trainer recovery microbenchmark: actual wall time of each
strategy's recovery actions (state restore, cache drop, agreement rounds)
at this machine's scale, plus fault-free step overhead."""
from __future__ import annotations

import statistics
import tempfile

from repro.configs import get_config, reduced
from repro.core import FailureType, FaultInjector
from repro.models.model import Model
from repro.train import AdamWConfig, TokenPipeline, TrainConfig, Trainer


def run(report=print):
    cfg = reduced(get_config("paper-demo"))
    model = Model(cfg)
    data = TokenPipeline(cfg.vocab_size, 4, 32, seed=7)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=12)
    for strategy in ["reinit", "ulfm", "cr"]:
        for kind in [FailureType.PROCESS, FailureType.NODE]:
            if strategy == "ulfm" and kind is FailureType.NODE:
                continue      # paper: ULFM node recovery not measurable
            with tempfile.TemporaryDirectory() as d:
                inj = FaultInjector(n_ranks=8, n_steps=12, kind=kind,
                                    seed=3)
                tc = TrainConfig(total_steps=12, ckpt_dir=d,
                                 strategy=strategy)
                tr = Trainer(model, data, opt, tc, injector=inj)
                res = tr.run()
                rep = res["reports"][0]
                steps = [l.seconds for l in tr.logs]
                report(f"trainer_{strategy}_{kind.value},"
                       f"{rep.total_s * 1e6:.0f},"
                       f"mpi_s={rep.mpi_recovery_s:.4f};"
                       f"ckpt_read_s={rep.ckpt_read_s:.4f};"
                       f"median_step_ms="
                       f"{statistics.median(steps) * 1e3:.1f}")


if __name__ == "__main__":
    run()
