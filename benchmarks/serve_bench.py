"""Fault-tolerant serving under live load: recovery cost per strategy.

Drives a two-rank `ServeCluster` through the `serve-rank-loss` shape —
a rank killed mid-decode under sustained open-loop load — once per
recovery strategy, and measures what a *serving* system actually loses
to a failure:

  tokens-to-first-recovered-token   tokens the surviving ranks deliver
                                    between the kill and the first new
                                    token from a request the dead rank
                                    owned (the client-visible gap);
  replayed (suppressed) tokens      decode work recomputed but never
                                    re-delivered — reinit's replay tax;
  requests dropped                  must be 0 for both strategies;
  wall seconds per delivered token  fault-free baseline throughput.

The counts are deterministic (seeded load, greedy decode), which makes
them ideal regression gates: any drift means the recovery semantics
changed, not the machine got slower.
"""
from __future__ import annotations

import time

_SETUP = None


def _setup():
    global _SETUP
    if _SETUP is None:
        import jax
        from repro.configs import get_config, reduced
        from repro.models.model import Model
        cfg = reduced(get_config("qwen2-7b"))
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _SETUP = (model, params)
    return _SETUP


def bench_serving(report=print, *, world: int = 2, n_slots: int = 4,
                  max_len: int = 64, rounds: int = 8, per_round: int = 1,
                  max_new: int = 5, seed: int = 7,
                  fault_round: int = 4, label: str = "serve") -> dict:
    from repro.serve import LoadGen, ServeCluster
    model, params = _setup()

    def load():
        return LoadGen(world=world, rounds=rounds, per_round=per_round,
                       max_new=max_new, seed=seed)

    out: dict = {"n_slots": n_slots, "world": world}

    # fault-free baseline: reference transcripts + steady-state rate
    base = ServeCluster(model, params, world=world, n_slots=n_slots,
                        max_len=max_len)
    t0 = time.perf_counter()
    m0 = base.run(load(), rounds=rounds)
    base_s = time.perf_counter() - t0
    ref = base.transcripts()
    out["tokens_total"] = m0["tokens_delivered"]
    out["s_per_token"] = base_s / max(1, m0["tokens_delivered"])
    report(f"{label}_faultfree,{out['s_per_token'] * 1e6:.0f},"
           f"tokens={out['tokens_total']}")

    for strategy in ("reinit", "replica"):
        c = ServeCluster(model, params, world=world, n_slots=n_slots,
                         max_len=max_len, strategy=strategy)
        t0 = time.perf_counter()
        m = c.run(load(), rounds=rounds,
                  fault={"round": fault_round, "rank": 1,
                         "point": "serve.decode.step"})
        wall = time.perf_counter() - t0
        kill = m["kills"][0]
        identical = c.transcripts() == ref
        out[strategy] = {
            "tokens_to_first_recovered_token":
                kill["tokens_to_first_recovered_token"],
            "rounds_down": kill["rounds_down"],
            "replayed_tokens": kill.get("replayed_tokens", 0),
            "requests_dropped": m["requests_dropped"],
            "bit_identical": identical,
            "wall_s": wall,
        }
        report(f"{label}_{strategy},{wall * 1e6:.0f},"
               f"ttfrt={kill['tokens_to_first_recovered_token']};"
               f"dropped={m['requests_dropped']};"
               f"identical={identical}")

    r, p = out["reinit"], out["replica"]
    if p["tokens_to_first_recovered_token"]:
        out["ttfrt_speedup"] = (r["tokens_to_first_recovered_token"]
                                / p["tokens_to_first_recovered_token"])
        report(f"{label}_ttfrt_speedup,0,x={out['ttfrt_speedup']:.2f}")
    return out


def run(report=print) -> dict:
    return bench_serving(report)


def run_wide(report=print) -> dict:
    """Nightly high-slot-count variant: a wide slot pool under heavier
    open-loop load (the serve-rank-loss-wide catalog cell's shape)."""
    return bench_serving(report, n_slots=16, rounds=10, per_round=3,
                         fault_round=5, label="serve_wide")


if __name__ == "__main__":
    run()
