"""Paper Figure 5: pure application time — ULFM's heartbeat drag."""
from __future__ import annotations

from repro.sim import APPS, simulate_run

RANKS = [16, 64, 256, 1024]


def run(report=print):
    for app_key, app in APPS.items():
        for n in RANKS:
            base = simulate_run(app, n, "reinit", "process").app_time_s
            for s in ["cr", "reinit", "ulfm"]:
                t = simulate_run(app, n, s, "process").app_time_s
                report(f"fig5_{app_key}_{s}_n{n},{t * 1e6:.0f},"
                       f"app_s={t:.3f};overhead_pct="
                       f"{100 * (t - base) / base:.2f}")


if __name__ == "__main__":
    run()
